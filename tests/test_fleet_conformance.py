"""Fleet/engine seam conformance: a routed fleet is the SAME system.

Two exact-equality pins (test_backend_conformance.py style, but across
the router seam instead of the backend seam):

* ``FleetModel`` with one replica is metric-identical to a bare
  ``ServingModel`` fed the same arrivals — routing through the fleet
  layer may not perturb a single replica's trajectory by even a float
  ulp.  Holds for every policy: with one replica, every policy is the
  identity.
* ``FleetModel`` with two round-robin replicas equals two independently
  fed ``ServingModel``s (arrivals dealt alternately).  Round-robin reads
  no replica state, so the fleet must not introduce extra sim-advance
  boundaries on the non-target replica.

Both lean on ``Sim.run`` pause-exactness (repro.sim.core): FleetModel
advances replicas in time slices to each routing decision, and a sliced
advance must reproduce an uninterrupted run's arithmetic bit-exactly.
"""
from __future__ import annotations

import dataclasses

import pytest

from repro.sim.serving import (FleetModel, ServingModel, ServingParams,
                               llama8b_tp4_params)

POLICIES = ("round-robin", "p2c", "affinity")


def _params(n_cores: int = 2) -> ServingParams:
    p = llama8b_tp4_params(n_cores=n_cores,
                           kv_capacity_tokens=256 * 64)
    return dataclasses.replace(p, timeout=20.0)


# enough arrivals to cover prefill chunking, batching, decode overlap
# and (last arrivals) queueing behind earlier work
ARRIVALS = [(0.05 * i, 192 + 64 * (i % 5), 4 + (i % 3), i % 7)
            for i in range(24)]
HORIZON = 40.0


def _metrics(res):
    reqs = res.unique_requests()
    return {
        "ttfts": [r.t_first_token for r in reqs],
        "dones": [r.t_done for r in reqs],
        "states": [r.state for r in reqs],
        "n_steps": res.sched_costs,
        "barrier_waits": res.barrier_waits,
        "dequeue_waits": res.dequeue_waits,
        "saturation_s": res.saturation_s,
    }


@pytest.mark.parametrize("policy", POLICIES)
def test_fleet_of_one_is_metric_identical_to_bare_model(policy):
    bare = ServingModel(_params())
    for t, n, mnt, stream in ARRIVALS:
        bare.add_request(t, n, max_new_tokens=mnt, stream=stream)
    ref = _metrics(bare.run(horizon=HORIZON))

    fleet = FleetModel(_params(), n_replicas=1, routing=policy)
    for t, n, mnt, stream in ARRIVALS:
        fleet.add_request(t, n, max_new_tokens=mnt, stream=stream)
    got = _metrics(fleet.run(horizon=HORIZON))

    assert got == ref                      # exact, not approximate


def test_two_replica_round_robin_equals_independent_replicas():
    refs = []
    for replica in range(2):
        m = ServingModel(_params())
        for i, (t, n, mnt, stream) in enumerate(ARRIVALS):
            if i % 2 == replica:
                m.add_request(t, n, max_new_tokens=mnt, stream=stream)
        refs.append(_metrics(m.run(horizon=HORIZON)))

    fleet = FleetModel(_params(), n_replicas=2, routing="round-robin")
    for t, n, mnt, stream in ARRIVALS:
        fleet.add_request(t, n, max_new_tokens=mnt, stream=stream)
    fleet_res = fleet.run(horizon=HORIZON)
    got = [_metrics(r) for r in fleet_res.per_replica]

    assert got == refs                     # exact, per replica
    # and the merged aggregate is the concatenation, not a re-derivation
    assert fleet_res.sched_costs == sum(r["n_steps"] for r in refs)
    assert fleet_res.saturation_s == sum(r["saturation_s"] for r in refs)


def test_fleet_requests_all_accounted_once():
    """No request lost or duplicated across the fleet seam: every
    arrival appears exactly once in the aggregated result, and the
    router's books are empty after the run."""
    fleet = FleetModel(_params(), n_replicas=2, routing="affinity")
    for t, n, mnt, stream in ARRIVALS:
        fleet.add_request(t, n, max_new_tokens=mnt, stream=stream)
    res = fleet.run(horizon=HORIZON)
    assert len(res.unique_requests()) == len(ARRIVALS)
    assert fleet.router.outstanding == {}
    assert fleet.router.stats()["inflight"] == [0, 0]
