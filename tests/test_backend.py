"""JaxBackend specifics: determinism, registry, prefix-page sharing.

The cross-backend conformance contract (same workload -> same completion
order/counts/tokens for every registered backend) lives in
tests/test_backend_conformance.py; this file keeps the jax-backend
deep-dives — deterministic sampling, swap round-trip page contents,
prefix-page sharing — plus the paged decode kernel against its gather
reference and the make_backend registry.
"""
from __future__ import annotations

import numpy as np
import pytest

from repro.backend import EmulatedBackend, StepResult, make_backend
from repro.backend.jax_backend import JaxBackend
from repro.core.devmodel import DeviceModel
from repro.serving.request import Request, RequestState
from repro.serving.scheduler import Scheduler, SchedulerConfig

BLOCK, NBLOCKS = 8, 64
SCHED_CFG = SchedulerConfig(
    max_num_seqs=8, max_tokens_per_step=64, prefill_chunk=16,
    enable_prefix_cache=True, block_size=BLOCK,
    kv_capacity_tokens=NBLOCKS * BLOCK)


def _workload():
    specs = [(21, 3, 1), (40, 5, 2), (21, 2, 1), (9, 4, 3)]
    reqs = []
    for n, max_new, stream in specs:
        r = Request(text="", max_new_tokens=max_new)
        base = stream << 10          # keep ids inside the tiny vocab range
        r.prompt_tokens = [base + (i % 700) for i in range(n)]
        reqs.append(r)
    return reqs


def _drive(backend, max_steps: int = 500):
    """Run the workload to completion; returns (completion order, counts,
    sampled tokens per request)."""
    sched = Scheduler(SCHED_CFG)
    reqs = _workload()
    for r in reqs:
        sched.add_request(r)
    idx_of = {r.req_id: i for i, r in enumerate(reqs)}   # workload position
    order, step = [], 0
    while sched.has_work and step < max_steps:
        plan = sched.schedule()
        if plan is None:
            break
        step += 1
        result = backend.execute(plan)
        assert isinstance(result, StepResult)
        assert result.step_id == plan.step_id
        for req in sched.complete_step(plan, float(step), result):
            order.append(idx_of[req.req_id])
            if hasattr(backend, "release"):
                backend.release(req.req_id)
    assert all(r.state == RequestState.FINISHED for r in reqs)
    counts = {idx_of[r.req_id]: len(r.generated) for r in reqs}
    tokens = {idx_of[r.req_id]: list(r.generated) for r in reqs}
    return order, counts, tokens


def test_emulated_jax_conformance():
    em_order, em_counts, _ = _drive(
        EmulatedBackend(DeviceModel(t_fixed=1e-5, t_prefill_tok=1e-8,
                                    t_decode_seq=1e-6)))
    jx_order, jx_counts, jx_tokens = _drive(
        JaxBackend(block_size=BLOCK, num_blocks=NBLOCKS, vocab=128,
                   interpret=True))
    assert em_order == jx_order
    assert em_counts == jx_counts
    # the jax backend actually samples (not the emulated placeholder 0)
    assert any(any(t != 0 for t in toks) for toks in jx_tokens.values())


def test_jax_backend_is_deterministic():
    _, _, a = _drive(JaxBackend(block_size=BLOCK, num_blocks=NBLOCKS,
                                vocab=128, interpret=True))
    _, _, b = _drive(JaxBackend(block_size=BLOCK, num_blocks=NBLOCKS,
                                vocab=128, interpret=True))
    assert a == b


def test_make_backend_registry():
    em = make_backend("emulated", device=DeviceModel())
    assert isinstance(em, EmulatedBackend)
    jx = make_backend("jax", scheduler_cfg=SCHED_CFG)
    assert isinstance(jx, JaxBackend)
    assert jx.num_blocks == SCHED_CFG.num_kv_blocks
    with pytest.raises(ValueError):
        make_backend("tpu")


def test_emulated_cost_includes_block_tables():
    from repro.serving.scheduler import StepPlan
    dev = DeviceModel(t_fixed=0.0, t_prefill_tok=0.0, t_decode_seq=0.0,
                      t_block_entry=1e-6)
    be = EmulatedBackend(dev, sleep=False)
    bare = StepPlan(1, [], [1], [])
    heavy = StepPlan(2, [], [1], [], block_tables={1: list(range(500))})
    assert be.step_cost(bare) == 0.0
    assert be.step_cost(heavy) == pytest.approx(500e-6)


def test_paged_kernel_matches_reference():
    import jax.numpy as jnp

    from repro.kernels.paged_decode_attention import (
        paged_decode_attention,
        paged_decode_attention_reference,
    )
    rng = np.random.default_rng(7)
    B, H, KV, D, N, blk, nb = 4, 8, 2, 16, 24, 8, 5
    q = jnp.asarray(rng.standard_normal((B, H, D)), jnp.float32)
    kp = jnp.asarray(rng.standard_normal((KV, N, blk, D)), jnp.float32)
    vp = jnp.asarray(rng.standard_normal((KV, N, blk, D)), jnp.float32)
    perm = rng.permutation(N)
    bt = np.full((B, nb), -1, np.int32)
    sl = np.zeros((B,), np.int32)
    lens = [37, 8, 0, 25]
    used = 0
    for b, n_tok in enumerate(lens):
        n_pages = -(-n_tok // blk)
        bt[b, :n_pages] = perm[used:used + n_pages]
        used += n_pages
        sl[b] = n_tok
    out = paged_decode_attention(q, kp, vp, jnp.asarray(bt),
                                 jnp.asarray(sl), interpret=True)
    ref = paged_decode_attention_reference(q, kp, vp, jnp.asarray(bt),
                                           jnp.asarray(sl))
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=1e-5, rtol=1e-5)


def test_jax_swap_round_trip_restores_identical_contents():
    """swap_out -> clobber the freed device pages -> restore into fresh
    pages: the restored KV is bit-identical to what was swapped out, even
    when the swap-out and the clobbering prefill ride the SAME plan (the
    Backend contract orders swap_outs before writes)."""
    from repro.serving.scheduler import StepPlan

    be = JaxBackend(block_size=8, num_blocks=16, num_swap_blocks=8,
                    vocab=64, interpret=True)
    toks = [3 + (i % 60) for i in range(16)]          # two full blocks
    be.execute(StepPlan(1, [(1, 0, 16)], [], [],
                        block_tables={1: [3, 7]}, new_tokens={1: toks}))
    snap_k = be.k_pages[:, [3, 7]].copy()
    snap_v = be.v_pages[:, [3, 7]].copy()
    assert np.abs(snap_k).sum() > 0               # prefill really wrote
    # one plan: park req 1's pages on host AND reuse its device blocks
    # for req 2's prefill
    clobber = [60 - (i % 50) for i in range(16)]
    be.execute(StepPlan(2, [(2, 0, 16)], [], [],
                        block_tables={2: [3, 7]}, new_tokens={2: clobber},
                        swap_outs={1: [(3, 0), (7, 1)]}))
    assert not np.array_equal(be.k_pages[:, [3, 7]], snap_k)  # clobbered
    np.testing.assert_array_equal(be.k_swap[:, [0, 1]], snap_k)
    # restore into different device blocks
    be.execute(StepPlan(3, [], [], [], restores={1: [(0, 10), (1, 11)]}))
    np.testing.assert_array_equal(be.k_pages[:, [10, 11]], snap_k)
    np.testing.assert_array_equal(be.v_pages[:, [10, 11]], snap_v)


def test_swap_policy_conformance_with_jax_backend():
    """End-to-end: the same pressured workload generates identical tokens
    under recompute and swap with the real (jax) backend — restored KV is
    indistinguishable from recomputed KV."""
    def drive(policy):
        cfg = SchedulerConfig(
            max_num_seqs=8, max_tokens_per_step=64, prefill_chunk=16,
            enable_prefix_cache=False, block_size=BLOCK,
            kv_capacity_tokens=9 * BLOCK,        # ~1.5 requests resident
            preemption_policy=policy,
            swap_capacity_tokens=32 * BLOCK)
        backend = JaxBackend(block_size=BLOCK, num_blocks=cfg.num_kv_blocks,
                             num_swap_blocks=cfg.num_swap_blocks,
                             vocab=128, interpret=True)
        sched = Scheduler(cfg)
        reqs = []
        for i, (n, m) in enumerate([(40, 8), (37, 8)]):
            r = Request(text="", max_new_tokens=m)
            base = (i + 1) << 10
            r.prompt_tokens = [3 + ((base + j) % 100) for j in range(n)]
            reqs.append(r)
            sched.add_request(r)
        step = 0
        while sched.has_work and step < 500:
            plan = sched.schedule()
            if plan is None:
                break
            step += 1
            sched.complete_step(plan, float(step), backend.execute(plan))
        assert all(r.state == RequestState.FINISHED for r in reqs)
        assert sched.blocks.free_blocks == sched.blocks.num_blocks
        evictions = sum(r.n_preemptions + r.n_swaps for r in reqs)
        return [list(r.generated) for r in reqs], evictions

    rec_tokens, rec_evictions = drive("recompute")
    swap_tokens, swap_evictions = drive("swap")
    assert rec_evictions >= 1 and swap_evictions >= 1, "expected pressure"
    assert rec_tokens == swap_tokens


def test_jax_backend_shares_prefix_pages():
    """Two requests with identical prompts: the scheduler hands the second
    the first's cached pages, and the jax backend decodes it correctly
    against KV it never wrote itself."""
    sched = Scheduler(SCHED_CFG)
    backend = JaxBackend(block_size=BLOCK, num_blocks=NBLOCKS, vocab=128,
                         interpret=True)

    def run_one(stream_tokens, max_new=3):
        r = Request(text="", max_new_tokens=max_new)
        r.prompt_tokens = list(stream_tokens)
        sched.add_request(r)
        step = 0
        while sched.has_work and step < 200:
            plan = sched.schedule()
            if plan is None:
                break
            step += 1
            res = backend.execute(plan)
            sched.complete_step(plan, float(step), res)
        assert r.state == RequestState.FINISHED
        return r

    prompt = [3 + (i % 90) for i in range(33)]
    a = run_one(prompt)
    b = run_one(prompt)
    assert b.prefilled >= 33 - BLOCK - 1 and b.prefilled > 0
    # same prompt + deterministic greedy sampling -> same continuation,
    # even though b's prefix KV lives in pages written for a
    assert b.generated[:3] == a.generated[:3]
