"""Speculative decode on the hybrid seam (docs/spec_decode.md).

The contract under test: greedy speculative decoding is a pure latency
optimization — emitted token streams are bit-identical to the
non-speculative path on every backend, with or without the async copy
engine, regardless of draft quality (a bad draft costs speed, never
correctness).  Plus the int8 KV decode tier: per-page quantization with
a provable error bound, swap round-trips that preserve codes and
scales, and the prefill->decode handoff as the precision seam.
"""
from __future__ import annotations

import dataclasses

import numpy as np
import pytest

from repro.backend import EmulatedBackend
from repro.backend.cpu_decode import CpuDecodeBackend
from repro.backend.hybrid import HybridBackend
from repro.backend.jax_backend import JaxBackend
from repro.core.devmodel import DeviceModel
from repro.serving.request import Request, RequestState
from repro.serving.scheduler import Scheduler, SchedulerConfig, StepPlan
from repro.spec import SpeculativeBackend

BLOCK = 8
BACKENDS = ("emulated", "jax", "cpu", "hybrid")


def _cfg(spec_k: int = 0, *, blocks: int = 64, **kw) -> SchedulerConfig:
    kw.setdefault("prefill_chunk", 16)
    return SchedulerConfig(
        max_num_seqs=8, max_tokens_per_step=64,
        block_size=BLOCK, kv_capacity_tokens=blocks * BLOCK,
        speculative_k=spec_k, **kw)


def _kw(cfg: SchedulerConfig, **extra) -> dict:
    return dict(block_size=cfg.block_size, num_blocks=cfg.num_kv_blocks,
                num_swap_blocks=max(cfg.num_swap_blocks, 1), vocab=128,
                interpret=True, copy_streams=cfg.copy_streams, **extra)


def _target(name: str, cfg: SchedulerConfig, kv_dtype: str = "float32"):
    kw = _kw(cfg)
    if name == "emulated":
        return EmulatedBackend(DeviceModel(t_fixed=1e-5, t_prefill_tok=1e-8,
                                           t_decode_seq=1e-6))
    if name == "jax":
        return JaxBackend(**_kw(cfg, kv_dtype=kv_dtype))
    if name == "cpu":
        return CpuDecodeBackend(**_kw(cfg, kv_dtype=kv_dtype))
    if name == "hybrid":
        return HybridBackend(JaxBackend(**kw),
                             CpuDecodeBackend(**_kw(cfg, kv_dtype=kv_dtype)),
                             t_handoff_block=1e-6,
                             copy_streams=cfg.copy_streams)
    raise AssertionError(name)


def _spec(name: str, cfg: SchedulerConfig, kv_dtype: str = "float32",
          draft_seed: int | None = None):
    target = _target(name, cfg, kv_dtype)
    if name == "emulated":
        draft = EmulatedBackend(DeviceModel(t_fixed=1e-5, t_prefill_tok=1e-8,
                                            t_decode_seq=1e-6))
    else:
        kw = _kw(cfg)
        if draft_seed is not None:
            kw["seed"] = draft_seed
        draft = CpuDecodeBackend(**kw)
    return SpeculativeBackend(draft, target)


def _req(n: int, max_new: int, stream: int = 1, eos: int = None) -> Request:
    r = Request(text="", max_new_tokens=max_new)
    r.prompt_tokens = [3 + (((stream << 10) + j) % 100) for j in range(n)]
    r.eos_token = eos
    return r


def _drive(backend, cfg: SchedulerConfig, reqs, max_plans: int = 500):
    """Run to completion; returns (token streams, n_plans, n_spec_plans)."""
    sched = Scheduler(cfg)
    for r in reqs:
        sched.add_request(r)
    plans = specs = 0
    seen = []
    while sched.has_work and plans < max_plans:
        plan = sched.schedule()
        if plan is None:
            break
        plans += 1
        specs += plan.speculative
        seen.append(plan)
        result = backend.execute(plan)
        for req in sched.complete_step(plan, float(plans), result):
            if hasattr(backend, "release"):
                backend.release(req.req_id)
    assert all(r.state == RequestState.FINISHED for r in reqs)
    assert sched.blocks.free_blocks == sched.blocks.num_blocks
    return [list(r.generated) for r in reqs], plans, specs, seen


# -- wire format ------------------------------------------------------------


def test_plan_roundtrip_speculative_fields():
    plan = StepPlan(7, [], [1, 2], [], num_steps=5, speculative=True,
                    decode_steps={1: 5, 2: 3},
                    draft_tokens={1: [9, 10, 11, 12], 2: [4, 5]})
    got = StepPlan.decode_bytes(plan.encode())
    assert got.speculative is True
    assert got.num_steps == 5
    assert got.decode_steps == {1: 5, 2: 3}
    # draft candidates are worker-side transient state: every worker
    # drafts deterministically from the same seed, so they never ship
    assert got.draft_tokens == {}


def test_plan_roundtrip_nonspec_carries_no_spec_fields():
    got = StepPlan.decode_bytes(StepPlan(3, [], [1], []).encode())
    assert got.speculative is False
    assert got.draft_tokens == {}


# -- scheduler: spec plan shape ---------------------------------------------


def test_scheduler_emits_spec_plans_when_decode_steady():
    """Decode-steady batches get speculative plans with a k+1 budget,
    clamped to the remaining token budget per request."""
    cfg = _cfg(spec_k=4)
    sched = Scheduler(cfg)
    a, b = _req(12, 9, 1), _req(12, 2, 2)
    sched.add_request(a)
    sched.add_request(b)
    step = 0
    spec_plans = []
    while sched.has_work and step < 50:
        plan = sched.schedule()
        if plan is None:
            break
        step += 1
        if plan.speculative:
            spec_plans.append(plan)
            for rid, budget in plan.decode_steps.items():
                req = a if rid == a.req_id else b
                rem = req.max_new_tokens - len(req.generated)
                assert budget == min(5, rem)  # k + 1, clamped to rem
            assert plan.num_steps == max(plan.decode_steps.values())
            assert not plan.prefill           # decode-steady only
        sched.complete_step(plan, float(step))
    assert spec_plans, "no speculative plan fired"
    assert any(p.num_steps == 5 for p in spec_plans)  # full budget early on


def test_spec_takes_precedence_over_multi_step():
    """With both enabled, eligible batches get a speculative plan, not a
    plain macro."""
    cfg = _cfg(spec_k=3, max_steps_per_dispatch=4)
    sched = Scheduler(cfg)
    sched.add_request(_req(12, 8, 1))
    step, saw_spec = 0, False
    while sched.has_work and step < 50:
        plan = sched.schedule()
        if plan is None:
            break
        step += 1
        if plan.num_steps > 1:
            assert plan.speculative
            saw_spec = True
        sched.complete_step(plan, float(step))
    assert saw_spec


# -- bit-identity across backends x copy engine -----------------------------


def _pressure_cfg(spec_k: int, copy_streams: int) -> SchedulerConfig:
    return SchedulerConfig(
        max_num_seqs=8, max_tokens_per_step=64, prefill_chunk=16,
        enable_prefix_cache=False, block_size=BLOCK,
        kv_capacity_tokens=12 * BLOCK,       # pressure: forces swap churn
        preemption_policy="swap", swap_capacity_tokens=32 * BLOCK,
        copy_streams=copy_streams, speculative_k=spec_k)


def _pressure_reqs():
    return [_req(n, m, stream=i + 1)
            for i, (n, m) in enumerate([(12, 12), (20, 9), (9, 12)])]


@pytest.fixture(scope="module")
def pressure_oracle():
    cfg = _pressure_cfg(0, 0)
    toks, _, specs, _ = _drive(CpuDecodeBackend(**_kw(cfg)), cfg,
                               _pressure_reqs())
    assert specs == 0
    return toks


@pytest.mark.parametrize("name", BACKENDS)
@pytest.mark.parametrize("streams", (0, 2))
def test_spec_bit_identical_under_pressure(name, streams, pressure_oracle):
    cfg = _pressure_cfg(4, streams)
    toks, _, specs, _ = _drive(_spec(name, cfg), cfg, _pressure_reqs())
    assert specs >= 1, "no speculative plan fired"
    if name == "emulated":                   # placeholder tokens: shape only
        assert [len(t) for t in toks] == [len(t) for t in pressure_oracle]
    else:
        assert toks == pressure_oracle


def test_divergent_draft_still_bit_identical():
    """A draft with a different seed produces garbage candidates; the
    verify step rejects them and the corrected stream is still identical
    (the draft only ever costs speed)."""
    cfg = _cfg(spec_k=4)
    oracle, _, _, _ = _drive(CpuDecodeBackend(**_kw(cfg)), _cfg(0),
                             [_req(12, 10, 1), _req(9, 8, 2)])
    sb = _spec("cpu", cfg, draft_seed=7)
    toks, _, specs, _ = _drive(sb, cfg, [_req(12, 10, 1), _req(9, 8, 2)])
    assert specs >= 1
    assert toks == oracle
    assert sb.n_accepted < sb.n_drafted      # the draft really is bad


def test_spec_eos_truncation_matches_oracle():
    """EOS inside an accepted run truncates the emitted stream exactly
    where the sequential path would have stopped."""
    base, _, _, _ = _drive(CpuDecodeBackend(**_kw(_cfg(0))), _cfg(0),
                           [_req(12, 10, 1)])
    eos = base[0][len(base[0]) // 2]         # a token mid-stream
    oracle, _, _, _ = _drive(CpuDecodeBackend(**_kw(_cfg(0))), _cfg(0),
                             [_req(12, 10, 1, eos=eos)])
    assert len(oracle[0]) < len(base[0])     # it actually truncated
    toks, _, specs, _ = _drive(_spec("cpu", _cfg(4)), _cfg(4),
                               [_req(12, 10, 1, eos=eos)])
    assert specs >= 1
    assert toks == oracle


# -- per-tier macros --------------------------------------------------------


def test_per_tier_macro_coexists_with_prefill():
    """With per_tier_macros, a macro decode plan may carry prefill
    chunks for other requests — and the streams still match the
    per-step oracle."""
    reqs = lambda: [_req(40, 8, 1), _req(30, 6, 2), _req(24, 6, 3)]
    oracle, _, _, _ = _drive(CpuDecodeBackend(**_kw(_cfg(0))), _cfg(0),
                             reqs())
    cfg = _cfg(0, max_steps_per_dispatch=4, per_tier_macros=True,
               prefill_chunk=8)
    toks, _, _, seen = _drive(CpuDecodeBackend(**_kw(cfg)), cfg, reqs())
    assert toks == oracle
    assert any(p.num_steps > 1 and p.prefill for p in seen), \
        "no macro plan carried a prefill chunk"


def test_per_tier_spec_with_prefill_in_flight():
    cfg = _cfg(4, per_tier_macros=True, prefill_chunk=8)
    oracle, _, _, _ = _drive(CpuDecodeBackend(**_kw(_cfg(0))), _cfg(0),
                             [_req(40, 8, 1), _req(24, 6, 2)])
    toks, _, specs, seen = _drive(_spec("cpu", cfg), cfg,
                                  [_req(40, 8, 1), _req(24, 6, 2)])
    assert specs >= 1
    assert toks == oracle
    assert any(p.speculative and p.prefill for p in seen), \
        "no speculative plan carried a prefill chunk"


# -- int8 KV tier -----------------------------------------------------------


def test_int8_quantization_error_bound():
    """Per-(head, page) symmetric quantization: half an LSB from the
    original rounding plus at most half an LSB per requant-on-growth.
    Incremental writes at different running maxima stay within a couple
    of LSBs at the final scale (measured 1.41 at this seed)."""
    cfg = _cfg(0)
    fp = CpuDecodeBackend(**_kw(cfg))
    q8 = CpuDecodeBackend(**_kw(cfg, kv_dtype="int8"))
    table = [0, 1, 2]
    rng = np.random.default_rng(11)
    for start, n in ((0, 7), (7, 9), (16, 8)):   # forces requants
        toks = rng.integers(3, 100, n)
        fp._write(table, start, toks)
        q8._write(table, start, toks)
    kf, vf = fp._gather_pages(np.asarray(table))
    kq, vq = q8._gather_pages(np.asarray(table))
    for got, want, scales in ((kq, kf, q8.k_scales), (vq, vf, q8.v_scales)):
        err = np.abs(got - want)             # [KV, n_pages, block, D]
        lsb = scales[:, table][:, :, None, None] / 127.0
        assert np.all(err <= 2.0 * lsb + 1e-7)


def test_int8_swap_round_trip_preserves_codes_and_scales():
    """swap-out -> clobber -> restore: codes AND per-page scales travel
    together, so the restored KV dequantizes bit-identically."""
    cfg = _cfg(0, preemption_policy="swap", swap_capacity_tokens=8 * BLOCK)
    be = CpuDecodeBackend(**_kw(cfg, kv_dtype="int8"))
    rng = np.random.default_rng(3)
    be._write([0, 1], 0, rng.integers(3, 100, 16))
    k0, v0 = be._gather_pages(np.asarray([0, 1]))
    be._copy_out([(0, 0), (1, 1)])           # park in host swap tier
    be._write([0, 1], 0, rng.integers(3, 100, 16))   # clobber dev pages
    be._copy_back([(0, 4), (1, 5)])          # restore into fresh pages
    k1, v1 = be._gather_pages(np.asarray([4, 5]))
    np.testing.assert_array_equal(k0, k1)
    np.testing.assert_array_equal(v0, v1)


def test_int8_handoff_quantizes_at_the_seam():
    """The prefill child keeps fp32; import_pages on an int8 decode child
    converts whole pages in one shot, within the quantization bound."""
    cfg = _cfg(0)
    pre = JaxBackend(**_kw(cfg))
    dec = CpuDecodeBackend(**_kw(cfg, kv_dtype="int8"))
    toks = np.arange(3, 3 + 16)
    pre._write([2, 3], 0, toks)
    dec.import_pages([2, 3], *pre.export_pages([2, 3]))
    assert dec.k_pages.dtype == np.int8
    kf, vf = pre._gather_pages(np.asarray([2, 3]))
    kq, vq = dec._gather_pages(np.asarray([2, 3]))
    for got, want, scales in ((kq, kf, dec.k_scales), (vq, vf, dec.v_scales)):
        bound = scales[:, [2, 3]][:, :, None, None] / 127.0
        assert np.all(np.abs(got - want) <= bound + 1e-7)


def test_spec_int8_deterministic():
    """spec + int8 decode tier may diverge token-wise from the fp32
    oracle (quantized logits), but it is deterministic run-to-run."""
    runs = []
    for _ in range(2):
        cfg = _cfg(4)
        sb = _spec("hybrid", cfg, kv_dtype="int8")
        toks, _, specs, _ = _drive(sb, cfg, [_req(12, 8, 1), _req(9, 6, 2)])
        assert specs >= 1
        runs.append(toks)
    assert runs[0] == runs[1]


# -- paged kernel: DMA path + int8 dequant-on-load --------------------------


def _paged_case(rng, *, int8: bool):
    import jax.numpy as jnp
    B, H, KV, D, N, blk, nb = 4, 8, 2, 16, 24, 8, 5
    q = jnp.asarray(rng.standard_normal((B, H, D)), jnp.float32)
    kf = rng.standard_normal((KV, N, blk, D)).astype(np.float32)
    vf = rng.standard_normal((KV, N, blk, D)).astype(np.float32)
    perm = rng.permutation(N)
    bt = np.full((B, nb), -1, np.int32)
    sl = np.zeros((B,), np.int32)
    used = 0
    for b, n_tok in enumerate([37, 8, 0, 25]):
        n_pages = -(-n_tok // blk)
        bt[b, :n_pages] = perm[used:used + n_pages]
        used += n_pages
        sl[b] = n_tok
    args = [jnp.asarray(bt), jnp.asarray(sl)]
    if not int8:
        return (q, jnp.asarray(kf), jnp.asarray(vf), *args), {}
    ks = np.abs(kf).max(axis=(2, 3)).astype(np.float32)      # [KV, N]
    vs = np.abs(vf).max(axis=(2, 3)).astype(np.float32)
    k8 = np.rint(kf / (ks[:, :, None, None] / 127.0)).astype(np.int8)
    v8 = np.rint(vf / (vs[:, :, None, None] / 127.0)).astype(np.int8)
    return ((q, jnp.asarray(k8), jnp.asarray(v8), *args),
            dict(k_scales=jnp.asarray(ks), v_scales=jnp.asarray(vs)))


@pytest.mark.parametrize("int8", (False, True))
def test_paged_kernel_hbm_path_matches_reference(int8):
    """Pool larger than the VMEM budget forces the DMA double-buffered
    path; it must match the gather reference (exactly for fp32, within
    the dequant bound for int8)."""
    from repro.kernels.paged_decode_attention import (
        paged_decode_attention,
        paged_decode_attention_reference,
    )
    args, kw = _paged_case(np.random.default_rng(7), int8=int8)
    out = paged_decode_attention(*args, **kw, vmem_budget_bytes=1024,
                                 interpret=True)
    ref = paged_decode_attention_reference(*args, **kw)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=1e-5, rtol=1e-5)


def test_paged_kernel_hbm_agrees_with_vmem_path():
    """Same inputs through both residency paths: identical numerics."""
    from repro.kernels.paged_decode_attention import paged_decode_attention
    args, kw = _paged_case(np.random.default_rng(9), int8=True)
    hbm = paged_decode_attention(*args, **kw, pool_in_vmem=False,
                                 interpret=True)
    vmem = paged_decode_attention(*args, **kw, pool_in_vmem=True,
                                  interpret=True)
    np.testing.assert_allclose(np.asarray(hbm), np.asarray(vmem),
                               atol=1e-6, rtol=1e-6)


def test_paged_kernel_int8_drift_vs_fp32_bounded():
    """int8 attention vs the fp32 oracle on the same values: the output
    drift stays within a loose bound (measured ~8e-3 at this shape)."""
    from repro.kernels.paged_decode_attention import (
        paged_decode_attention,
        paged_decode_attention_reference,
    )
    rng = np.random.default_rng(7)
    fp_args, _ = _paged_case(rng, int8=False)
    q_args, q_kw = _paged_case(np.random.default_rng(7), int8=True)
    want = paged_decode_attention_reference(*fp_args)
    got = paged_decode_attention(*q_args, **q_kw, vmem_budget_bytes=1024,
                                 interpret=True)
    rows = np.asarray(fp_args[4]) > 0        # seq_len 0 rows are inert
    drift = np.abs(np.asarray(got) - np.asarray(want))[rows].max()
    assert drift < 0.05, drift


# -- DES integration --------------------------------------------------------


def test_sim_with_speculative_runs_and_fires_spec_plans():
    from repro.sim.serving import (ServingModel, llama8b_tp4_params,
                                   with_speculative)
    params = with_speculative(llama8b_tp4_params(1), k=4, accept_rate=0.8,
                              kv_dtype="int8")
    model = ServingModel(params)
    for i in range(3):
        model.add_request(0.0, 64, max_new_tokens=24, stream=i)
    res = model.run(horizon=200.0)
    assert all(r.t_done for r in res.requests)
    assert sum(p.speculative for p in model._plans.values()) >= 1
    # spec plans collapse dispatch rounds vs one-step-per-token
    assert len(model._plans) < 3 * 24
