"""Fleet router / autoscaler invariants (incl. hypothesis).

The router contract under test (docs/fleet.md):

* prefix summaries are blooms — false positives allowed, false
  negatives NEVER at build time;
* affinity routes a repeated prefix back to the same replica while that
  replica's pressure stays below the hysteresis band, diverts while it
  is drowning, and returns after recovery;
* p2c never knowingly routes into a replica with zero free KV blocks
  while an alternative exists;
* dispatch bookkeeping can neither leak nor double-count a request
  across done/abort/drain interleavings:
  ``sum(inflight) == len(outstanding)`` always;
* fleet-aggregated metrics count one logical request once, even after a
  retry left records on two replicas (``_dedup_by_rid``).
"""
from __future__ import annotations

import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # container ships no hypothesis — deterministic sweep
    from _hypothesis_fallback import given, settings, strategies as st

from repro.fleet import (AutoscalerConfig, FleetAutoscaler, FleetRouter,
                         PrefixSummary, ReplicaSignals, RouterConfig,
                         leading_block_keys, leading_word_keys)
from repro.serving.blocks import chain_key
from repro.serving.request import Request
from repro.serving.scheduler import PressureStats
from repro.sim.serving import _dedup_by_rid


def _stats(free=64, total=64, queue=0, running=0, sat=0.0, summary=None):
    return PressureStats(step_id=0, free_blocks=free, total_blocks=total,
                         queue_depth=queue, n_running=running, n_swapped=0,
                         n_restoring=0, in_flight_copies=0,
                         kv_used_tokens=0, cached_blocks=0, n_preempted=0,
                         n_timed_out=0, cpu_saturation=sat,
                         prefix_summary=summary)


def _prompt(stream: int, n: int = 64):
    base = stream << 24
    return list(range(base, base + n))


# -- prefix summaries --------------------------------------------------------


@settings(max_examples=30)
@given(st.lists(st.integers(min_value=-2**62, max_value=2**62),
                max_size=200))
def test_bloom_no_false_negatives(keys):
    s = PrefixSummary.from_keys(keys)
    assert all(s.might_contain(k) for k in keys)
    assert len(s) == len(keys)


def test_bloom_union_covers_both_sides():
    a = PrefixSummary.from_keys([1, 2, 3])
    b = PrefixSummary.from_keys([100, 200])
    u = a.union(b)
    assert all(u.might_contain(k) for k in (1, 2, 3, 100, 200))
    with pytest.raises(AssertionError):
        a.union(PrefixSummary(n_bits=1024))


def test_leading_block_keys_match_blockmanager_chain():
    toks = _prompt(7, 200)
    keys = leading_block_keys(toks, 64)
    # same chain BlockManager registers: k_i = chain_key(k_{i-1}, block_i)
    k = 0
    expect = []
    for i in range(0, 128 + 1, 64):      # 3 full blocks of 64 in 200
        k = chain_key(k, toks[i:i + 64])
        expect.append(k)
    assert keys == expect
    assert leading_block_keys(toks[:63], 64) == []          # no full block
    assert len(leading_block_keys(_prompt(1, 4096), 64, 8)) == 8


def test_leading_word_keys_prefix_sharing():
    shared = "tok " * 64
    a = leading_word_keys(shared + "alpha beta " * 16)
    b = leading_word_keys(shared + "gamma delta " * 16)
    n_shared = 64 // 16
    assert a[:n_shared] == b[:n_shared]
    assert a[n_shared:] != b[n_shared:]
    assert leading_word_keys("too short") == []


# -- routing policies --------------------------------------------------------


def test_router_config_validation():
    with pytest.raises(ValueError):
        RouterConfig(policy="random")
    with pytest.raises(ValueError):
        RouterConfig(pressure_high=0.5, pressure_low=0.6)
    with pytest.raises(ValueError):
        FleetRouter(0)
    with pytest.raises(ValueError):
        FleetRouter(2, stats_fns=[lambda: None])


def test_round_robin_cycles_and_respects_exclude():
    r = FleetRouter(3, RouterConfig(policy="round-robin"))
    assert [r.route([]) for _ in range(6)] == [0, 1, 2, 0, 1, 2]
    assert r.route([], exclude=(0,)) != 0
    # excluding everything is ignored (routing somewhere beats dropping)
    assert r.route([], exclude=(0, 1, 2)) in (0, 1, 2)


def test_affinity_sticky_below_band_diverts_above_returns_after():
    cfg = RouterConfig(block_size=8, queue_norm=4.0)   # band at 3.4/2.4
    r = FleetRouter(2, cfg)
    p = _prompt(1, 64)
    i0 = r.route(p)
    assert r.route(p) == i0                 # optimistic-bloom stickiness
    assert r.n_affinity_hits >= 1
    i1 = 1 - i0
    # saturate i0's pressure proxy (inflight/queue_norm >= 0.85)
    for rid in range(4):
        r.record_dispatch(rid, i0)
    div = r.route(p)
    assert div == i1                        # drowning replica is avoided
    assert r.n_pressure_diversions == 1
    r.record_dispatch(100, i1)              # the diverted request, in flight
    # mid-band is still drowning (hysteresis: exit only below pressure_low)
    r.record_done(0)                        # 3/4 = 0.75, inside the band
    assert r.route(p) == i1
    for rid in (1, 2, 3):
        r.record_done(rid)                  # 0/4 — fully recovered
    assert r.route(p) == i0                 # load tie-break favours home


def test_session_affinity_covers_unseen_prefix():
    r = FleetRouter(2, RouterConfig(block_size=8))
    first = r.route(_prompt(5, 64), session="s5")
    # a follow-up turn with a DIFFERENT (uncached) prompt still lands on
    # the session's replica
    assert r.route(_prompt(6, 64), session="s5") == first
    assert r.n_session_hits == 1


def test_p2c_never_picks_zero_free_blocks_when_alternative_exists():
    snaps = [_stats(free=0, queue=0), _stats(free=8, queue=50)]
    r = FleetRouter(2, RouterConfig(policy="p2c"),
                    stats_fns=[lambda: snaps[0], lambda: snaps[1]])
    # replica 1 is far more loaded, but replica 0 cannot admit at all
    assert all(r.route(_prompt(i, 16)) == 1 for i in range(40))


def test_p2c_all_full_still_routes():
    r = FleetRouter(2, RouterConfig(policy="p2c"),
                    stats_fns=[lambda: _stats(free=0)] * 2)
    assert r.route(_prompt(1, 16)) in (0, 1)


def test_p2c_prefers_lower_load():
    snaps = [_stats(queue=30, running=30), _stats(queue=0)]
    r = FleetRouter(2, RouterConfig(policy="p2c"),
                    stats_fns=[lambda: snaps[0], lambda: snaps[1]])
    hits = sum(r.route(_prompt(i, 16)) == 1 for i in range(40))
    assert hits == 40


def test_affinity_respects_snapshot_summary():
    # authoritative path: replica 1's scheduler-published bloom holds the
    # prefix even though the router never dispatched it there
    keys = leading_block_keys(_prompt(9, 64), 8)
    summary = PrefixSummary.from_keys(keys)
    r = FleetRouter(2, RouterConfig(block_size=8),
                    stats_fns=[lambda: _stats(),
                               lambda: _stats(summary=summary)])
    assert r.route(_prompt(9, 64)) == 1


# -- bookkeeping -------------------------------------------------------------


@settings(max_examples=40)
@given(st.lists(st.integers(min_value=0, max_value=399), max_size=120))
def test_router_bookkeeping_never_leaks(ops):
    """Random dispatch/done/abort/drain interleavings: inflight counters
    and the outstanding map never diverge, go negative, or double-free."""
    n = 3
    r = FleetRouter(n, RouterConfig(policy="round-robin"))
    next_rid = 0
    live = []
    for v in ops:
        op = v % 4
        if op == 0:                                  # dispatch
            idx = r.route([])
            r.record_dispatch(next_rid, idx)
            live.append(next_rid)
            next_rid += 1
        elif op == 1 and live:                       # done
            rid = live.pop((v // 4) % len(live))
            assert r.record_done(rid) is not None
            assert r.record_done(rid) is None        # idempotent
        elif op == 2 and live:                       # abort
            rid = live.pop((v // 4) % len(live))
            assert r.record_abort(rid) is not None
        elif op == 3:                                # replica drain
            idx = (v // 4) % n
            orphans = r.drain(idx)
            live = [rid for rid in live if rid not in orphans]
            assert r._inflight[idx] == 0
        assert all(c >= 0 for c in r._inflight)
        assert sum(r._inflight) == len(r.outstanding)
        assert sorted(r.outstanding) == sorted(live)


def test_double_dispatch_asserts():
    r = FleetRouter(2, RouterConfig(policy="round-robin"))
    r.record_dispatch(1, 0)
    with pytest.raises(AssertionError):
        r.record_dispatch(1, 1)


# -- fleet-level dedup (the retry double-count fix) --------------------------


def _rec(rid, t_first=None, arrival=0.0):
    r = Request(text="", max_new_tokens=1, req_id=rid)
    r.t_arrival = arrival
    r.t_first_token = t_first
    return r


def test_dedup_by_rid_completed_record_wins():
    timed_out = _rec(7)                    # starved on replica A
    completed = _rec(7, t_first=3.0)       # retried, finished on replica B
    out = _dedup_by_rid([timed_out, completed, _rec(8)])
    assert [r.req_id for r in out] == [7, 8]
    assert out[0].t_first_token == 3.0     # one request, zero timeouts
    # two timeout records still collapse to ONE timeout
    out = _dedup_by_rid([_rec(9), _rec(9)])
    assert len(out) == 1 and out[0].t_first_token is None


# -- autoscaler --------------------------------------------------------------


def test_autoscaler_scales_up_after_window_of_starvation():
    sc = FleetAutoscaler(2, AutoscalerConfig(window=3))
    starved = [ReplicaSignals(cpu_saturation=0.99, timeout_rate=0.1),
               ReplicaSignals()]
    acts = [sc.observe(starved).action for _ in range(3)]
    assert acts == ["hold", "hold", "scale_up"]
    rec = sc.observe(starved)
    assert rec.target == 3 and "replica 0" in rec.reason
    # signal-only: the caller acts, then resets the streaks via resize
    sc.resize(rec.target)
    assert sc.n == 3
    assert sc.observe(starved + [ReplicaSignals()]).action == "hold"


def test_autoscaler_scales_down_when_idle_and_respects_floor():
    sc = FleetAutoscaler(2, AutoscalerConfig(window=2, min_replicas=1))
    idle = [ReplicaSignals(cpu_saturation=0.01)] * 2
    assert sc.observe(idle).action == "hold"
    rec = sc.observe(idle)
    assert rec.action == "scale_down" and rec.target == 1
    sc.resize(rec.target)
    # at the floor, sustained idleness is a hold, not a recommendation
    sc2 = FleetAutoscaler(1, AutoscalerConfig(window=1, min_replicas=1))
    assert sc2.observe([ReplicaSignals()]).action == "hold"


def test_autoscaler_kv_pressure_needs_preemption_too():
    sc = FleetAutoscaler(1, AutoscalerConfig(window=1, max_replicas=4))
    full_but_quiet = [ReplicaSignals(kv_pressure=0.99, preempt_rate=0.0,
                                     cpu_saturation=0.5)]
    assert sc.observe(full_but_quiet).action == "hold"
    thrashing = [ReplicaSignals(kv_pressure=0.99, preempt_rate=0.9,
                                cpu_saturation=0.5)]
    assert sc.observe(thrashing).action == "scale_up"
