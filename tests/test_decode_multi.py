"""Fused multi-step decode == sequential decode_step loop (greedy)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import model as M

from conftest import tiny


@pytest.mark.parametrize("name", ["qwen2-0.5b", "falcon-mamba-7b",
                                  "gemma3-12b"])
def test_decode_multi_matches_sequential(name, rng):
    cfg = tiny(name)
    params = M.init_params(rng, cfg)
    B, n_pre, k = 2, 6, 4
    toks = jax.random.randint(jax.random.PRNGKey(9), (B, n_pre),
                              0, cfg.vocab_size)
    logits, cache = M.prefill(params, cfg, toks)
    specs = M.cache_specs(cfg, B, n_pre + k)
    cache = jax.tree.map(
        lambda c, s: jnp.pad(c, [(0, d - g) for g, d in
                                 zip(c.shape, s.shape)]), cache, specs)

    first = jnp.argmax(logits[:, 0, : cfg.vocab_size], -1
                       ).astype(jnp.int32)[:, None]

    # sequential oracle
    seq_out = []
    c1, tok, clen = cache, first, n_pre
    for _ in range(k):
        lg, c1 = M.decode_step(params, cfg, tok, c1, jnp.int32(clen))
        nxt = jnp.argmax(lg[:, 0, : cfg.vocab_size], -1).astype(jnp.int32)
        seq_out.append(nxt)
        tok = nxt[:, None]
        clen += 1
    seq_out = jnp.stack(seq_out, 1)

    # fused
    fused, _, new_clen = M.decode_multi(params, cfg, first, cache,
                                        jnp.int32(n_pre), k)
    np.testing.assert_array_equal(np.asarray(fused), np.asarray(seq_out))
    assert int(new_clen) == n_pre + k


def test_decode_multi_eos_masking(rng):
    cfg = tiny("olmo-1b")
    params = M.init_params(rng, cfg)
    B, n_pre, k = 1, 4, 6
    toks = jax.random.randint(jax.random.PRNGKey(2), (B, n_pre),
                              0, cfg.vocab_size)
    logits, cache = M.prefill(params, cfg, toks)
    specs = M.cache_specs(cfg, B, n_pre + k)
    cache = jax.tree.map(
        lambda c, s: jnp.pad(c, [(0, d - g) for g, d in
                                 zip(c.shape, s.shape)]), cache, specs)
    first = jnp.argmax(logits[:, 0, : cfg.vocab_size], -1
                       ).astype(jnp.int32)[:, None]
    # force eos = whatever the first generated token is => everything after
    # must repeat eos
    eos = int(jnp.argmax(
        M.decode_step(params, cfg, first, cache, jnp.int32(n_pre))[0]
        [:, 0, : cfg.vocab_size], -1)[0])
    out, _, _ = M.decode_multi(params, cfg, first, cache, jnp.int32(n_pre),
                               k, eos_id=eos)
    got = np.asarray(out)[0]
    assert got[0] == eos
    assert (got == eos).all()
