"""Speed-bump harness pins: the zero-overhead oracle and trace contract.

The profiling subsystem (repro.profiling, docs/profiling.md) is only
trustworthy if measuring changes nothing: an engine run with tracing
enabled and zero injected delay must be *bit-identical* to an
uninstrumented run — same completion order, same token streams — and a
DES run with a zero-delay profiler must land on exactly the same event
arithmetic as one with no profiler at all.  That oracle is pinned here
across every backend and the copy-stream / multi-step axes, alongside:

  * spec-grammar units (``parse_inject`` accepts, rejects, overrides);
  * trace well-formedness properties under preempt/swap/restore/abort
    churn (spans balanced and non-negative, completion-ordered per
    role, every recorded request id was actually admitted);
  * Chrome-trace export round-trip + critical-path-summary invariants
    (``0 <= exposed <= total`` per site, device spans are the cover
    set, never a summarized site);
  * the monotone-sensitivity regression: injecting delay at the
    scheduler site never *increases* DES throughput, and the
    amplification slope (makespan seconds lost per second injected —
    the cross-budget metric benchmarks/speed_bump.py fits) is at least
    as steep at 1 core as at 32 — the paper's thesis as a regression
    test.
"""
from __future__ import annotations

import dataclasses
import json
import os
import tempfile

import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                                       # pragma: no cover
    from _hypothesis_fallback import given, settings, strategies as st

from repro import profiling
from repro.backend import EmulatedBackend
from repro.core.devmodel import DeviceModel
from repro.profiling import (SITES, Profiler, ProfilingConfig, SpanEvent,
                             critical_path_summary, events_from_stats,
                             export_chrome_trace, format_summary,
                             parse_inject)
from repro.serving.request import Request, RequestState
from repro.serving.scheduler import Scheduler, SchedulerConfig
from repro.sim.serving import (ServingModel, llama8b_tp4_params,
                               with_async_copies, with_multi_step)

BLOCK, NBLOCKS, NSWAP = 8, 64, 32

# ~1.5 requests resident under swap: preempt/swap/restore churn
# (mirrors the pressure configs of the conformance + copy-engine suites)
def pressure_cfg(copy_streams: int = 0, multi_step: int = 1,
                 **kw) -> SchedulerConfig:
    return SchedulerConfig(
        max_num_seqs=8, max_tokens_per_step=64, prefill_chunk=16,
        enable_prefix_cache=False, block_size=BLOCK,
        kv_capacity_tokens=9 * BLOCK, preemption_policy="swap",
        swap_capacity_tokens=NSWAP * BLOCK, copy_streams=copy_streams,
        max_steps_per_dispatch=multi_step, **kw)


def make(name: str, cfg: SchedulerConfig):
    from repro.backend.cpu_decode import CpuDecodeBackend
    from repro.backend.hybrid import HybridBackend
    from repro.backend.jax_backend import JaxBackend
    kw = dict(block_size=cfg.block_size, num_blocks=cfg.num_kv_blocks,
              num_swap_blocks=cfg.num_swap_blocks,
              copy_streams=cfg.copy_streams, vocab=128, interpret=True)
    if name == "emulated":
        return EmulatedBackend(DeviceModel(t_fixed=1e-5, t_prefill_tok=1e-8,
                                           t_decode_seq=1e-6,
                                           copy_streams=cfg.copy_streams))
    if name == "jax":
        return JaxBackend(**kw)
    if name == "cpu":
        return CpuDecodeBackend(**kw)
    if name == "hybrid":
        return HybridBackend(JaxBackend(**kw), CpuDecodeBackend(**kw),
                             t_handoff_block=1e-6,
                             copy_streams=cfg.copy_streams)
    raise AssertionError(name)


def _reqs(specs):
    out = []
    for i, (n, m) in enumerate(specs):
        r = Request(text="", max_new_tokens=m)
        base = (i + 1) << 10
        r.prompt_tokens = [3 + ((base + j) % 100) for j in range(n)]
        out.append(r)
    return out


def _drive(backend, cfg, reqs, max_steps=800):
    """Run to completion; (completion order by workload position, token
    counts, token streams) — the bit-identity triple."""
    sched = Scheduler(cfg)
    for r in reqs:
        sched.add_request(r)
    idx_of = {r.req_id: i for i, r in enumerate(reqs)}
    order, step = [], 0
    while sched.has_work and step < max_steps:
        plan = sched.schedule()
        if plan is None:
            break
        step += 1
        res = backend.execute(plan)
        for req in sched.complete_step(plan, float(step), res):
            order.append(idx_of[req.req_id])
            if hasattr(backend, "release"):
                backend.release(req.req_id)
    assert all(r.state == RequestState.FINISHED for r in reqs)
    counts = {idx_of[r.req_id]: len(r.generated) for r in reqs}
    tokens = {idx_of[r.req_id]: list(r.generated) for r in reqs}
    return order, counts, tokens


# -- spec grammar ------------------------------------------------------------


def test_parse_inject_grammar():
    assert parse_inject("") == {}
    assert parse_inject("scheduler=100") == \
        {"scheduler": pytest.approx(100e-6)}
    # the speed-bump exemplar's colon separator is accepted too
    assert parse_inject("dispatch:250") == \
        {"dispatch": pytest.approx(250e-6)}
    # '*' targets the whole catalogue; later entries override
    d = parse_inject("*=100,tokenize=0")
    assert set(d) == set(SITES)
    assert d["tokenize"] == 0.0
    assert d["scheduler"] == pytest.approx(100e-6)
    with pytest.raises(ValueError, match="unknown injection site"):
        parse_inject("schedular=100")          # typo must not fit a 0 slope
    with pytest.raises(ValueError, match="negative"):
        parse_inject("scheduler=-5")


def test_profiling_config_gate(monkeypatch):
    assert not ProfilingConfig().enabled
    assert ProfilingConfig(inject="*=0").enabled      # explicit zeros count
    assert ProfilingConfig(trace=True).enabled
    # an all-default config installs nothing: the fast path stays None
    monkeypatch.delenv(profiling.ENV_INJECT, raising=False)
    monkeypatch.delenv(profiling.ENV_TRACE, raising=False)
    assert profiling.activate(ProfilingConfig()) is None
    assert profiling.active() is None
    # the env spec reaches entry points that never touch the config
    monkeypatch.setenv(profiling.ENV_INJECT, "scheduler=42")
    prof = profiling.activate(ProfilingConfig(), role="envtest")
    try:
        assert prof is not None
        assert prof.delays["scheduler"] == pytest.approx(42e-6)
    finally:
        profiling.deactivate()
    assert profiling.active() is None


# -- zero-overhead oracle (live scheduler + backend path) --------------------


@pytest.mark.parametrize("name", ("emulated", "jax", "cpu", "hybrid"))
def test_oracle_traced_run_bit_identical(name):
    """Tracing on, delays zero: the instrumented run's completion order,
    token counts, and token streams equal the uninstrumented run's —
    across copy_streams {0, 2} x multi-step {1, 4} on every backend.
    Measurement must not perturb the thing measured."""
    specs = [(40, 8), (37, 8)]
    for streams in (0, 2):
        for k in (1, 4):
            cfg = pressure_cfg(copy_streams=streams, multi_step=k)
            base = _drive(make(name, cfg), cfg, _reqs(specs))
            prof = profiling.activate(
                ProfilingConfig(inject="*=0", trace=True), role="oracle")
            try:
                traced = _drive(make(name, cfg), cfg, _reqs(specs))
            finally:
                profiling.deactivate()
            assert traced == base, (name, streams, k)
            # the oracle is only meaningful if instrumentation really ran
            assert any(ev.site == "block_alloc" for ev in prof.events), \
                (name, streams, k)
            if streams > 0:
                assert any(ev.site == "copy_submit" for ev in prof.events)
            assert prof.charged == 0.0


# -- zero-overhead oracle (DES) ----------------------------------------------


def _des_run(params, n_req=5):
    model = ServingModel(params)
    for i in range(n_req):
        model.add_request(0.05 * i, 600, max_new_tokens=24, stream=i)
    res = model.run(horizon=120.0)
    sig = [(r.t_arrival, r.t_first_token, r.t_done, len(r.generated))
           for r in res.requests]
    assert all(r.t_done for r in res.requests)
    return res, sig


@pytest.mark.parametrize("variant", ("plain", "copies", "multistep"))
def test_oracle_des_zero_delay_bit_exact(variant):
    """A profiler whose delays are all zero is indistinguishable from no
    profiler: identical sim_time, scheduler-invocation count, and
    per-request timestamps — not approximately, exactly.  This is what
    licenses leaving the instrumentation compiled into the sim procs."""
    params = llama8b_tp4_params(2, preemption_policy="swap",
                                kv_capacity_tokens=4096)
    if variant == "copies":
        params = with_async_copies(params, copy_streams=2)
    elif variant == "multistep":
        params = with_multi_step(params, k=4)
    base_res, base_sig = _des_run(params)
    prof_res, prof_sig = _des_run(
        dataclasses.replace(params, inject="*=0"))
    assert prof_sig == base_sig
    assert prof_res.sched_costs == base_res.sched_costs
    # and a non-zero delay visibly moves the same signature (the oracle
    # is falsifiable: the injection path really is wired in)
    _, bumped_sig = _des_run(
        dataclasses.replace(params, inject="scheduler=5000"))
    assert bumped_sig != base_sig
    assert max(t for *_, t, _ in bumped_sig) > \
        max(t for *_, t, _ in base_sig)


# -- trace well-formedness under churn ----------------------------------------


@settings(max_examples=12, deadline=None)
@given(st.lists(st.integers(min_value=9, max_value=44), min_size=2,
                max_size=5),
       st.integers(min_value=3, max_value=10),
       st.integers(min_value=2, max_value=7))
def test_trace_wellformed_under_churn(prompt_lens, timeout, abort_every):
    """Under swap/restore churn with aborts landing at arbitrary points
    (including while a restore copy is in flight, and mid-macro): every
    span closes with non-negative duration, instants have zero duration,
    per-role events are ordered by completion time (the append order a
    lock-free list gives), and every event that names a request names
    one that was actually admitted."""
    cfg = pressure_cfg(copy_streams=2, multi_step=4)
    reqs = _reqs([(n, 2 + n % 7) for n in prompt_lens])
    prof = profiling.activate(ProfilingConfig(inject="*=0", trace=True),
                              role="churn")
    try:
        sched = Scheduler(cfg)
        backend = make("emulated", cfg)
        for r in reqs:
            sched.add_request(r)
        admitted = {r.req_id for r in reqs}
        step, n_sched_calls = 0, 0
        while sched.has_work and step < 600:
            with prof.span("scheduler", step=sched.step_id):
                plan = sched.schedule()
            n_sched_calls += 1
            if plan is None:
                break
            step += 1
            if step % abort_every == 0:
                # expire() is the abort path: anything older than the
                # timeout drops, whatever state it is in (RESTORING
                # included — the abort-while-restoring seam)
                sched.expire(float(step), float(timeout))
            res = backend.execute(plan)
            sched.complete_step(plan, float(step), res)
    finally:
        profiling.deactivate()
    events = prof.events
    assert events, "churn run recorded nothing"
    done = 0.0
    for ev in events:
        assert ev.dur >= 0.0
        if ev.instant:
            assert ev.dur == 0.0
        # append order == completion order within one role's list
        assert ev.t0 + ev.dur >= done
        done = ev.t0 + ev.dur
        if ev.req is not None:
            assert ev.req in admitted, ev
        assert ev.site in SITES or ev.site in ("device", "barrier")
    # spans balanced: one scheduler span per schedule() call, no more
    n_sched_spans = sum(1 for ev in events
                        if ev.site == "scheduler" and not ev.instant)
    assert n_sched_spans == n_sched_calls


# -- export round trip + critical-path summary --------------------------------


@settings(max_examples=15, deadline=None)
@given(st.lists(st.integers(min_value=0, max_value=10_000), min_size=1,
                max_size=40),
       st.integers(min_value=1, max_value=3))
def test_chrome_trace_export_roundtrip(raw, n_roles):
    """Arbitrary merged event soup -> valid trace_event JSON: one record
    per event, timestamps rebased non-negative, durations non-negative,
    instants flagged, one thread_name metadata record per role."""
    pairs = []
    for i, v in enumerate(raw):
        role = f"role{v % n_roles}"
        site = SITES[v % len(SITES)] if v % 3 else "device"
        pairs.append((role, SpanEvent(site, t0=100.0 + (v % 97) * 1e-4,
                                      dur=(v % 13) * 1e-5,
                                      step=v % 7 or None,
                                      req=v % 5 or None,
                                      instant=(v % 11 == 0))))
    pairs.sort(key=lambda p: p[1].t0)
    path = os.path.join(tempfile.mkdtemp(), "trace.json")
    n = export_chrome_trace(pairs, path)
    assert n == len(pairs)
    doc = json.loads(open(path).read())
    evs = doc["traceEvents"]
    meta = [e for e in evs if e.get("ph") == "M"]
    body = [e for e in evs if e.get("ph") in ("X", "i")]
    assert len(body) == len(pairs)
    assert len(meta) == len({role for role, _ in pairs})
    for e in body:
        assert e["ts"] >= 0.0
        if e["ph"] == "X":
            assert e["dur"] >= 0.0
        else:
            assert e["s"] == "t"
    # summary invariants: device is the cover set, never a row; exposed
    # time is bounded by total time per site
    summary = critical_path_summary(pairs)
    assert "device" not in summary
    for site, s in summary.items():
        assert 0.0 <= s["exposed_s"] <= s["total_s"] + 1e-12, site
        assert s["count"] >= 1
    assert format_summary(summary).splitlines()  # renders without blowing up


def test_critical_path_summary_overlap_math():
    """Hand-built timeline: a span fully covered by device time exposes
    nothing, a half-covered one exposes exactly the uncovered half."""
    pairs = events_from_stats([
        {"role": "w0", "trace_events": [SpanEvent("device", 0.0, 10.0)]},
        {"role": "eng", "trace_events": [
            SpanEvent("scheduler", 2.0, 4.0),          # inside device
            SpanEvent("shm_encode", 8.0, 4.0),         # half exposed
            SpanEvent("tokenize", 20.0, 3.0),          # fully exposed
            SpanEvent("block_alloc", 1.0, 0.0, instant=True),
        ]},
    ])
    s = critical_path_summary(pairs)
    assert s["scheduler"]["exposed_s"] == pytest.approx(0.0)
    assert s["shm_encode"]["exposed_s"] == pytest.approx(2.0)
    assert s["tokenize"]["exposed_s"] == pytest.approx(3.0)
    assert s["block_alloc"]["total_s"] == 0.0          # instants: count only
    assert s["block_alloc"]["count"] == 1


# -- monotone sensitivity (the thesis as a regression test) -------------------


def _bump_run(n_cores: int, inject: str):
    params = llama8b_tp4_params(n_cores, preemption_policy="swap",
                                kv_capacity_tokens=3_520)
    params = with_async_copies(params, copy_streams=2)
    params = dataclasses.replace(params, inject=inject)
    model = ServingModel(params)
    for i in range(6):
        model.add_request(0.0, 800, max_new_tokens=256, stream=i)
    res = model.run(horizon=300.0)
    done = [r for r in res.requests if r.t_done]
    assert len(done) == 6, "sweep workload must complete"
    toks = sum(len(r.generated) for r in done)
    makespan = max(r.t_done for r in done)
    charged = model.prof.charged if model.prof is not None else 0.0
    return toks / makespan, makespan, charged


def test_scheduler_bump_monotone_and_sharper_when_starved():
    """Slowing the scheduler can only hurt: DES throughput is
    non-increasing in the injected delay at every core budget.  And the
    amplification slope — makespan seconds lost per second of delay
    actually charged — is steeper at 1 core than at 32: with cores to
    spare the bump hides behind the device (amplification ~<= 1), while
    under GPS contention every injected second also delays everyone
    sharing the core (the paper's CPU-starvation thesis, quantified)."""
    amps = {}
    for cores in (1, 32):
        tput0, makespan0, _ = _bump_run(cores, "")
        prev = tput0
        pts = []
        for delay_us in (300.0, 1000.0):
            tput, makespan, charged = _bump_run(
                cores, f"scheduler={delay_us:g}")
            assert charged > 0.0
            assert tput <= prev + 1e-9, \
                f"throughput rose with delay at {cores} cores"
            prev = tput
            pts.append((charged, makespan - makespan0))
        # least squares through the origin: seconds lost per second injected
        amps[cores] = (sum(c * d for c, d in pts)
                       / sum(c * c for c, _ in pts))
    assert amps[1] >= amps[32], amps
    # starved amplification really is contention (> 1), not pass-through
    assert amps[1] > 1.0, amps
